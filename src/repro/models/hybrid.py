"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block.

The shared transformer block (attention + SwiGLU, one set of weights) is
re-applied after every ``attn_every`` Mamba-2 blocks — the Zamba trick, and
architecturally the same move as Plaid's domain-specialized PCU: one
hardwired, reused unit serving many sites. Per-site LoRA deltas from the
paper's checkpoint are omitted (documented simplification).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import dense as D
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.layers import Spec


def n_groups(cfg) -> Tuple[int, int]:
    g = cfg.n_layers // cfg.attn_every
    rem = cfg.n_layers - g * cfg.attn_every
    return g, rem


def shared_block_spec(cfg) -> Dict[str, Spec]:
    return {
        "attn": L.attention_param_spec(cfg),
        "mlp": L.mlp_param_spec(cfg),
        "ln1": Spec((cfg.d_model,), ("embed",), init="ones"),
        "ln2": Spec((cfg.d_model,), ("embed",), init="ones"),
    }


def param_spec(cfg) -> Dict[str, Spec]:
    return {
        **L.embed_param_spec(cfg),
        "mamba": S._stack(S.mamba2_param_spec(cfg), cfg.n_layers),
        "shared": shared_block_spec(cfg),
        "ln_f": Spec((cfg.d_model,), ("embed",), init="ones"),
    }


def _split_groups(cfg, stacked):
    """(L, ...) stacked mamba weights -> ((G, k, ...), (rem, ...))."""
    g, rem = n_groups(cfg)
    k = cfg.attn_every
    grouped = jax.tree.map(lambda t: t[: g * k].reshape((g, k) + t.shape[1:]), stacked)
    tail = jax.tree.map(lambda t: t[g * k :], stacked)
    return grouped, tail


def _shared_attn(cfg, shared, x, positions, *, want_kv=False):
    h, kv = L.attention_layer(
        cfg, shared["attn"], L.rms_norm(x, shared["ln1"]), positions, attn_impl=cfg.attn_impl
    )
    x = x + h
    x = x + L.swiglu(shared["mlp"], L.rms_norm(x, shared["ln2"]))
    return (x, kv) if want_kv else (x, None)


def forward(cfg, params, batch) -> jax.Array:
    x = L.embed_lookup(params["emb"], batch["tokens"])
    B, T = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    grouped, tail = _split_groups(cfg, params["mamba"])
    shared = params["shared"]

    def mamba_step(xx, ww):
        h, _ = S.mamba2_block(cfg, ww, L.rms_norm(xx, ww["ln"]))
        return xx + h, None

    policy = L.remat_policy(cfg.remat)
    mamba_step_c = jax.checkpoint(mamba_step, policy=policy) if policy else mamba_step

    def group(xx, ws):
        xx, _ = lax.scan(mamba_step_c, xx, ws)
        xx, _ = _shared_attn(cfg, shared, xx, positions)
        return xx, None

    x, _ = L.scan_layers(cfg, group, x, grouped)
    g, rem = n_groups(cfg)
    if rem:
        x, _ = L.scan_layers(cfg, mamba_step_c, x, tail)
    return L.rms_norm(x, params["ln_f"])


def loss_fn(cfg, params, batch):
    h = forward(cfg, params, batch)
    nll = L.chunked_xent(h, params["emb"], batch["labels"], cfg.logits_chunk)
    return nll, {"loss": nll}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def cache_spec(cfg, batch: int, seq_len: int) -> Dict[str, Spec]:
    Di, N, K = cfg.d_inner, cfg.ssm_state, cfg.d_conv
    H, P = cfg.n_ssm_heads, cfg.d_inner // cfg.n_ssm_heads
    g, _ = n_groups(cfg)
    kvd = cfg.n_kv_heads * cfg.resolved_head_dim
    seq_axis = "cache_seq" if batch == 1 else None
    return {
        "conv": Spec((cfg.n_layers, batch, K - 1, Di), ("layers", "batch", None, "mlp")),
        "h": Spec(
            (cfg.n_layers, batch, H, P, N), ("layers", "batch", None, "mlp", "state"), jnp.float32
        ),
        # one KV cache per shared-attention application site
        "k": Spec((g, batch, seq_len, kvd), ("layers", "batch", seq_axis, "kv_heads")),
        "v": Spec((g, batch, seq_len, kvd), ("layers", "batch", seq_axis, "kv_heads")),
        "pos": Spec((batch, seq_len), ("batch", seq_axis), jnp.int32),
        "length": Spec((batch,), ("batch",), jnp.int32),
    }


def prefill(cfg, params, batch):
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = L.embed_lookup(params["emb"], tokens)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    grouped, tail = _split_groups(cfg, params["mamba"])
    shared = params["shared"]

    def mamba_step(xx, ww):
        zero = {
            "conv": jnp.zeros((B, cfg.d_conv - 1, cfg.d_inner), xx.dtype),
            "h": jnp.zeros(
                (B, cfg.n_ssm_heads, cfg.d_inner // cfg.n_ssm_heads, cfg.ssm_state), jnp.float32
            ),
        }
        h, c = S.mamba2_block(cfg, ww, L.rms_norm(xx, ww["ln"]), zero)
        return xx + h, c

    policy = L.remat_policy(cfg.remat)
    mamba_step_c = jax.checkpoint(mamba_step, policy=policy) if policy else mamba_step

    def group(xx, ws):
        xx, caches = lax.scan(mamba_step_c, xx, ws)
        xx, (k, v) = _shared_attn(cfg, shared, xx, positions, want_kv=True)
        return xx, (caches, k.reshape(B, T, -1), v.reshape(B, T, -1))

    x, (gcaches, ks, vs) = L.scan_layers(cfg, group, x, grouped)
    g, rem = n_groups(cfg)
    conv = gcaches["conv"].reshape((g * cfg.attn_every,) + gcaches["conv"].shape[2:])
    hst = gcaches["h"].reshape((g * cfg.attn_every,) + gcaches["h"].shape[2:])
    if rem:
        x, tcaches = lax.scan(mamba_step_c, x, tail)
        conv = jnp.concatenate([conv, tcaches["conv"]], 0)
        hst = jnp.concatenate([hst, tcaches["h"]], 0)
    x = L.rms_norm(x, params["ln_f"])
    logits = (x[:, -1:] @ params["emb"].T).astype(jnp.float32)
    cache = {
        "conv": conv,
        "h": hst,
        "k": ks,
        "v": vs,
        "pos": jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T)),
        "length": jnp.full((B,), T, jnp.int32),
    }
    return cache, logits


def decode_step(cfg, params, cache, tokens):
    B = tokens.shape[0]
    Smax = cache["k"].shape[2]
    hd = cfg.resolved_head_dim
    length = cache["length"]
    positions = length[:, None].astype(jnp.int32)
    x = L.embed_lookup(params["emb"], tokens)
    slot = (length % Smax).astype(jnp.int32)
    barange = jnp.arange(B)
    new_pos = cache["pos"].at[barange, slot].set(length)
    valid = (new_pos >= 0) & (new_pos <= length[:, None])
    grouped, tail = _split_groups(cfg, params["mamba"])
    shared = params["shared"]
    g, rem = n_groups(cfg)
    k_every = cfg.attn_every

    def mamba_dec(xx, scan_in):
        ww, conv, h = scan_in
        out, nc = S.mamba2_block(cfg, ww, L.rms_norm(xx, ww["ln"]), {"conv": conv, "h": h})
        return xx + out, (nc["conv"], nc["h"])

    def group(carry, scan_in):
        xx = carry
        ws, conv_g, h_g, kc, vc = scan_in
        xx, (nconv, nh) = lax.scan(mamba_dec, xx, (ws, conv_g, h_g))
        # shared attention with this site's KV cache
        hh = L.rms_norm(xx, shared["ln1"])
        q, k, v = L.attention_qkv(cfg, shared["attn"], hh, positions)
        kc = kc.at[barange, slot].set(k.reshape(B, -1))
        vc = vc.at[barange, slot].set(v.reshape(B, -1))
        o = L.decode_attention(
            q, kc.reshape(B, Smax, cfg.n_kv_heads, hd), vc.reshape(B, Smax, cfg.n_kv_heads, hd), valid
        )
        xx = xx + o.reshape(B, 1, -1) @ shared["attn"]["wo"]
        xx = xx + L.swiglu(shared["mlp"], L.rms_norm(xx, shared["ln2"]))
        return xx, (nconv, nh, kc, vc)

    conv_g = cache["conv"][: g * k_every].reshape((g, k_every) + cache["conv"].shape[1:])
    h_g = cache["h"][: g * k_every].reshape((g, k_every) + cache["h"].shape[1:])
    x, (nconv, nh, ks, vs) = L.scan_layers(cfg, group, x, (grouped, conv_g, h_g, cache["k"], cache["v"]))
    conv = nconv.reshape((g * k_every,) + nconv.shape[2:])
    hst = nh.reshape((g * k_every,) + nh.shape[2:])
    if rem:
        x, (tconv, th) = lax.scan(
            mamba_dec, x, (tail, cache["conv"][g * k_every :], cache["h"][g * k_every :])
        )
        conv = jnp.concatenate([conv, tconv], 0)
        hst = jnp.concatenate([hst, th], 0)
    x = L.rms_norm(x, params["ln_f"])
    logits = (x @ params["emb"].T).astype(jnp.float32)
    new_cache = {
        "conv": conv,
        "h": hst,
        "k": ks,
        "v": vs,
        "pos": new_pos,
        "length": length + 1,
    }
    return new_cache, logits
