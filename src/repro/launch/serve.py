"""Serving launcher (smoke: reduced config on CPU).

PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_3b --smoke --new-tokens 8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import zoo
from repro.models.layers import init_of
from repro.serve.loop import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = init_of(zoo.param_spec(cfg), jax.random.PRNGKey(0))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size,
        dtype=jnp.int32,
    )
    extra = None
    if cfg.family == "encdec":
        extra = {
            "audio_embeds": jax.random.normal(
                jax.random.PRNGKey(2), (args.batch, cfg.enc_seq, cfg.d_model),
                jnp.bfloat16,
            )
        }
    elif cfg.family == "vlm":
        B, T = args.batch, args.prompt_len
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        extra = {
            "embeds": jax.random.normal(jax.random.PRNGKey(2), (B, T, cfg.d_model), jnp.bfloat16),
            "positions": jnp.stack([pos, pos, pos], axis=1),
        }
    tokens, info = generate(cfg, params, prompts, max_new_tokens=args.new_tokens, extra_batch=extra)
    print("generated:", tokens.tolist())
    print("info:", info)


if __name__ == "__main__":
    main()
