"""Roofline analysis from the compiled dry-run (assignment §ROOFLINE).

XLA's cost_analysis counts a while-loop (scan) body once, so per-layer
costs are recovered by compiling small *unrolled* models at 2–3 layer-count
points and extrapolating linearly (exact for layer-homogeneous stacks):

  dense/moe/ssm/vlm :  total(L) = (2-L)·C(1) + (L-1)·C(2)
  encdec            :  total(4) = -2·C(1) + 3·C(2)           (enc=dec=L)
  hybrid (zamba2)   :  total = -36·A + 5·B + 32·C with
                       A=(k=1,L=1)  B=(k=1,L=2)  C=(k=2,L=2)
                       (38 mamba blocks + 6 shared-attn applications)

Each point is one subprocess dry-run (512 host devices), cached as JSON.
Terms (TPU v5e):  compute = FLOPs/dev / 197 TF/s ;  memory = bytes/dev /
819 GB/s ;  collective = coll-bytes/dev / 50 GB/s.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline --sweep [--multi-pod]
  PYTHONPATH=src python -m repro.launch.roofline --table   # print terms
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

OUT_DIR = "experiments/roofline"
DRY_DIR = "experiments/dryrun"


def points_for(cfg) -> List[Tuple[str, Dict, float]]:
    """(tag, cfg overrides, combination coefficient) per family."""
    L = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn = L // cfg.attn_every
        # solve: A = base+m+a ; B = base+2m+2a ; C = base+2m+a
        # => m = C-A ; a = B-C ; base = 2A-B
        # total = base + L·m + n·a = (2-L)·A + (n-1)·B + (L-n)·C
        return [
            ("A", {"unroll_layers": True, "n_layers": 1, "attn_every": 1}, 2 - L),
            ("B", {"unroll_layers": True, "n_layers": 2, "attn_every": 1}, n_attn - 1),
            ("C", {"unroll_layers": True, "n_layers": 2, "attn_every": 2}, L - n_attn),
        ]
    if cfg.family == "encdec":
        E = cfg.n_enc_layers
        assert E == L, "extrapolation assumes enc==dec layer count"
        return [
            ("A", {"unroll_layers": True, "n_layers": 1, "n_enc_layers": 1}, 2 - L),
            ("B", {"unroll_layers": True, "n_layers": 2, "n_enc_layers": 2}, L - 1),
        ]
    return [
        ("A", {"unroll_layers": True, "n_layers": 1}, 2 - L),
        ("B", {"unroll_layers": True, "n_layers": 2}, L - 1),
    ]


def _cell_path(arch, shape, multi_pod, tag, extra=""):
    mp = "mp" if multi_pod else "sp"
    suf = f"__{extra}" if extra else ""
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mp}__{tag}{suf}.json")


def run_point(arch, shape, multi_pod, tag, overrides, *, extra_overrides=None,
              extra_tag="", timeout=1800) -> Optional[Dict]:
    path = _cell_path(arch, shape, multi_pod, tag, extra_tag)
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "ok":
            return rec
    os.makedirs(OUT_DIR, exist_ok=True)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--json", path]
    if multi_pod:
        cmd.append("--multi-pod")
    ov = dict(overrides)
    if extra_overrides:
        ov.update(extra_overrides)
    for k, v in ov.items():
        cmd += ["--set", f"{k}={json.dumps(v)}"]
    env = dict(os.environ, PYTHONPATH="src")
    p = subprocess.run(cmd, capture_output=True, text=True, env=env, timeout=timeout)
    if p.returncode != 0:
        print(f"[roofline FAIL] {arch} {shape} {tag}: {p.stderr[-500:]}")
        return None
    with open(path) as f:
        return json.load(f)


def combine(points: List[Tuple[Dict, float]]) -> Dict[str, float]:
    """Linear combination of per-device costs across extrapolation points."""
    out = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0}
    for rec, coef in points:
        out["flops"] += coef * rec.get("flops_per_device", 0.0)
        out["bytes"] += coef * rec.get("bytes_per_device", 0.0)
        coll = rec.get("collectives", {})
        out["coll_bytes"] += coef * sum(v["bytes"] for v in coll.values())
    return out


def analyze_cell(arch: str, shape_name: str, multi_pod: bool = False,
                 extra_overrides=None, extra_tag: str = "") -> Optional[Dict]:
    from repro.configs import SHAPES, get_config, shape_applicable

    cfg = get_config(arch)
    if extra_overrides:
        cfg = cfg.replace(**{k: v for k, v in extra_overrides.items()
                             if k not in ("n_layers", "n_enc_layers", "attn_every")})
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    pts = []
    for tag, ov, coef in points_for(get_config(arch)):
        rec = run_point(arch, shape_name, multi_pod, tag, ov,
                        extra_overrides=extra_overrides, extra_tag=extra_tag)
        if rec is None or rec.get("status") != "ok":
            return None
        pts.append((rec, coef))
    tot = combine(pts)
    n_chips = 512 if multi_pod else 256
    compute_t = tot["flops"] / PEAK_FLOPS
    memory_t = tot["bytes"] / HBM_BW
    coll_t = tot["coll_bytes"] / ICI_BW
    dominant = max(
        (("compute", compute_t), ("memory", memory_t), ("collective", coll_t)),
        key=lambda kv: kv[1],
    )[0]
    # MODEL_FLOPS (6ND train / 2ND decode; N_active for MoE)
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        model_flops = 2 * n_active * shape.global_batch
    hlo_flops_global = tot["flops"] * n_chips
    return {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "extra": extra_tag,
        "flops_per_device": tot["flops"],
        "bytes_per_device": tot["bytes"],
        "coll_bytes_per_device": tot["coll_bytes"],
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": coll_t,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_ratio": model_flops / hlo_flops_global if hlo_flops_global else None,
        "roofline_fraction": (
            (model_flops / n_chips / PEAK_FLOPS)
            / max(compute_t, memory_t, coll_t)
            if max(compute_t, memory_t, coll_t) > 0 else None
        ),
    }


def sweep(multi_pod: bool = False, only: Optional[str] = None):
    from repro.configs import ARCH_IDS, SHAPES

    out = {}
    for arch in ARCH_IDS:
        if only and arch != only:
            continue
        for shape in SHAPES:
            r = analyze_cell(arch, shape, multi_pod)
            if r is None:
                print(f"[no data] {arch} {shape}")
                continue
            out[f"{arch}__{shape}"] = r
            if "skipped" not in r:
                print(f"{arch:22s} {shape:12s} comp={r['compute_s']*1e3:8.2f}ms "
                      f"mem={r['memory_s']*1e3:8.2f}ms coll={r['collective_s']*1e3:8.2f}ms "
                      f"dom={r['dominant']:10s} frac={r['roofline_fraction'] and round(r['roofline_fraction'],3)}",
                      flush=True)
    path = os.path.join(OUT_DIR, f"summary_{'mp' if multi_pod else 'sp'}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--arch")
    args = ap.parse_args()
    if args.sweep:
        sweep(args.multi_pod, only=args.arch)


if __name__ == "__main__":
    main()
