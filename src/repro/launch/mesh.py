"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before the first jax initialization.

Mesh layout:
  single-pod : (data=16, model=16)            — 256 chips (one v5e pod)
  multi-pod  : (pod=2, data=16, model=16)     — 512 chips across DCN

'model' is the innermost axis (ICI-nearest) because TP collectives are the
most latency-sensitive; 'pod' is outermost (DCN). Scales to 1000+ nodes by
growing 'pod' (pure DP + gradient sync) without touching in-pod shardings.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(*, model: int = 1):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def validate_mesh(mesh) -> dict:
    """Sanity facts recorded in EXPERIMENTS.md §Dry-run."""
    return {
        "shape": dict(mesh.shape),
        "n_devices": mesh.devices.size,
        "axis_names": list(mesh.axis_names),
    }
