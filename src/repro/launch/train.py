"""Training launcher.

Smoke (CPU):      PYTHONPATH=src python -m repro.launch.train --arch qwen3_14b --smoke --steps 3
Production lower: the dry-run (repro.launch.dryrun) is the no-hardware path;
on a real pod this module runs the same ``make_train_step`` under
``make_production_mesh()`` with the same shardings.
"""
from __future__ import annotations

import argparse
import logging

from repro.configs import SHAPES, RunConfig, get_config, smoke_config
from repro.configs.base import ShapeSpec
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true", help="reduced config on CPU")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--grad-compression", default="none", choices=["none", "int8"])
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    if args.smoke:
        cfg = smoke_config(args.arch)
        shape = ShapeSpec("smoke", args.seq, args.batch, "train")
    else:
        cfg = get_config(args.arch)
        shape = SHAPES[args.shape]
    run = RunConfig(
        model=cfg, shape=shape, checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every, total_steps=max(args.steps, 10),
        grad_compression=args.grad_compression,
    )
    out = train(run, steps=args.steps)
    print(f"final step {out['final_step']}  losses: "
          f"{[round(l, 4) for l in out['losses'][-5:]]}  "
          f"stragglers: {out['stragglers']}")


if __name__ == "__main__":
    main()
