import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production meshes and record memory/cost/collective
analysis. This is how the distribution config is proven coherent without
real hardware (assignment: MULTI-POD DRY-RUN).

Single-cell mode (in-process):
    PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm_12b \
        --shape train_4k [--multi-pod] [--json out.json]

Sweep mode (one subprocess per cell so each gets a clean jax runtime):
    PYTHONPATH=src python -m repro.launch.dryrun --sweep --out experiments/dryrun
"""

import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, RunConfig, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh, validate_mesh
from repro.models import zoo
from repro.models.layers import shapes_of
from repro.parallel import sharding as shard_lib
from repro.train import optimizer as opt_lib
from repro.train import steps as steps_lib

# ---------------------------------------------------------------------------
# HLO collective parsing (collective bytes are NOT in cost_analysis)
# ---------------------------------------------------------------------------

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_OP_RE = re.compile(
    r"^\s*(?:%|ROOT\s+%?)?[\w.\-]+\s*=\s*(?:\(?)([a-z0-9]+)\[([0-9,]*)\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start|-done)?\("
)
_TUPLE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: op count and per-device bytes moved.

    Bytes = the op's *output* shape(s) (the data a device materializes from
    the wire — for all-reduce equal to input). Ops inside a `while` body are
    counted once, matching cost_analysis semantics; the roofline harness
    applies the same trip-count extrapolation to both.
    """
    out = {k: {"count": 0, "bytes": 0.0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-start(" in line and ("-done" not in line):
            pass  # async start carries the shapes; -done repeats them — count starts only
        if "-done(" in line or "-done " in line:
            continue
        m = None
        kind = None
        for k in COLLECTIVES:
            if f" {k}(" in line or f" {k}-start(" in line:
                kind = k
                break
        if kind is None:
            continue
        lhs = line.split("=", 1)[0] if "=" in line else ""
        rhs = line.split("=", 1)[1] if "=" in line else line
        # result type(s) are the first shape literal(s) on the rhs before the op name
        head = rhs.split(kind)[0]
        shapes = _TUPLE_RE.findall(head)
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    return out


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------


def build_cell(arch: str, shape_name: str, multi_pod: bool, *, mesh=None, cfg_overrides=None):
    """Returns (jitted_fn, arg_shapes (ShapeDtypeStructs), donate, meta)."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, None, None, {"skipped": why}
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    run = RunConfig(model=cfg, shape=shape, multi_pod=multi_pod)

    pspec = zoo.param_spec(cfg)
    p_shapes = shapes_of(pspec)
    p_shard = shard_lib.shardings_for(pspec, mesh, cfg, multi_pod=multi_pod)
    in_spec = zoo.input_spec(cfg, shape)
    b_shapes = shapes_of(in_spec)
    b_shard = shard_lib.shardings_for(in_spec, mesh, cfg, multi_pod=multi_pod)

    if shape.kind == "train":
        ocfg = opt_lib.AdamWConfig(state_dtype=cfg.opt_state_dtype)
        ospec = opt_lib.opt_state_spec(pspec, ocfg)
        o_shapes = shapes_of(ospec)
        o_shard = shard_lib.shardings_for(ospec, mesh, cfg, multi_pod=multi_pod)
        fn = steps_lib.make_train_step(cfg, run)
        args = (p_shapes, o_shapes, b_shapes)
        in_sh = (p_shard, o_shard, b_shard)
        out_struct = jax.eval_shape(fn, *args)
        rep = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
        out_sh = (
            p_shard,
            o_shard,
            jax.tree.map(lambda _: rep, out_struct[2]),
        )
        jf = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(0, 1))
    elif shape.kind == "prefill":
        fn = steps_lib.make_prefill_step(cfg)
        args = (p_shapes, b_shapes)
        in_sh = (p_shard, b_shard)
        cspec = zoo.cache_spec(cfg, shape.global_batch, shape.seq_len)
        # prefill's cache seq extent can be window-limited for SWA archs
        out_struct = jax.eval_shape(fn, *args)
        c_shard = _cache_shardings_from_struct(out_struct[0], cfg, mesh, multi_pod, shape)
        rep = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
        out_sh = (c_shard, rep)
        jf = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    else:  # decode
        fn = steps_lib.make_serve_step(cfg)
        cspec = zoo.cache_spec(cfg, shape.global_batch, shape.seq_len)
        c_shapes = shapes_of(cspec)
        c_shard = shard_lib.shardings_for(cspec, mesh, cfg, multi_pod=multi_pod)
        args = (p_shapes, c_shapes, b_shapes["tokens"])
        tok_sh = shard_lib.shardings_for(
            {"t": zoo.input_spec(cfg, shape)["tokens"]}, mesh, cfg, multi_pod=multi_pod
        )["t"]
        in_sh = (p_shard, c_shard, tok_sh)
        rep = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
        out_sh = (c_shard, rep, rep)
        jf = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(1,))
    meta = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "multi_pod": multi_pod,
        "mesh": validate_mesh(mesh),
        "params": cfg.param_count(),
        "active_params": cfg.param_count(active_only=True),
    }
    return jf, args, mesh, meta


def _cache_shardings_from_struct(cache_struct, cfg, mesh, multi_pod, shape):
    """Build shardings for a prefill-produced cache from its actual shapes."""
    cspec = zoo.cache_spec(cfg, shape.global_batch, shape.seq_len)
    # prefill may produce a shorter (window) cache: rebuild specs with actual shapes
    from repro.models.layers import Spec, spec_map

    def fix(spec, struct):
        return Spec(tuple(struct.shape), spec.axes, struct.dtype, spec.init)

    fixed = jax.tree.map(
        fix, cspec, cache_struct, is_leaf=lambda x: isinstance(x, Spec)
    )
    return shard_lib.shardings_for(fixed, mesh, cfg, multi_pod=multi_pod)


# ---------------------------------------------------------------------------
# Cell execution
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, cfg_overrides=None) -> Dict:
    t0 = time.time()
    jf, args, mesh, meta = build_cell(arch, shape_name, multi_pod, cfg_overrides=cfg_overrides)
    if jf is None:
        return meta  # skipped
    rec = dict(meta)
    with jax.set_mesh(mesh):
        t1 = time.time()
        lowered = jf.lower(*args)
        rec["lower_s"] = round(time.time() - t1, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    print(compiled.memory_analysis())  # proves it fits
    ca = compiled.cost_analysis()
    print({k: ca.get(k) for k in ("flops", "bytes accessed")})
    for field in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        rec[field] = int(getattr(ma, field, 0) or 0)
    rec["flops_per_device"] = float(ca.get("flops", 0.0))
    rec["bytes_per_device"] = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    rec["collectives"] = parse_collectives(hlo)
    rec["hlo_lines"] = hlo.count("\n")
    rec["total_s"] = round(time.time() - t0, 2)
    rec["status"] = "ok"
    return rec


# ---------------------------------------------------------------------------
# Sweep driver
# ---------------------------------------------------------------------------


def all_cells():
    for arch in ARCH_IDS:
        for shape_name in SHAPES:
            for multi_pod in (False, True):
                yield arch, shape_name, multi_pod


def sweep(out_dir: str, skip_existing: bool = True, only_arch: Optional[str] = None):
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for arch, shape_name, multi_pod in all_cells():
        if only_arch and arch != only_arch:
            continue
        tag = f"{arch}__{shape_name}__{'mp' if multi_pod else 'sp'}"
        path = os.path.join(out_dir, tag + ".json")
        if skip_existing and os.path.exists(path):
            print(f"[skip existing] {tag}")
            continue
        cfg = get_config(arch)
        ok, why = shape_applicable(cfg, SHAPES[shape_name])
        if not ok:
            rec = {
                "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "skipped": why,
            }
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"[skip rule] {tag}: {why}")
            continue
        print(f"[cell] {tag} ...", flush=True)
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape_name, "--json", path,
        ]
        if multi_pod:
            cmd.append("--multi-pod")
        env = dict(os.environ)
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        t0 = time.time()
        p = subprocess.run(cmd, capture_output=True, text=True, env=env, timeout=3600)
        dt = time.time() - t0
        if p.returncode != 0:
            rec = {
                "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "error", "stderr": p.stderr[-4000:], "wall_s": round(dt, 1),
            }
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"[FAIL] {tag} ({dt:.0f}s)\n{p.stderr[-1500:]}")
        else:
            print(f"[ok] {tag} ({dt:.0f}s)")
        results.append(tag)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-skip-existing", action="store_true")
    ap.add_argument("--set", action="append", default=[], help="cfg override k=v")
    args = ap.parse_args()

    if args.sweep:
        sweep(args.out, skip_existing=not args.no_skip_existing, only_arch=args.arch)
        return

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except Exception:
            pass
        overrides[k] = v
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod, cfg_overrides=overrides or None)
    except Exception:
        rec = {
            "arch": args.arch, "shape": args.shape, "multi_pod": args.multi_pod,
            "status": "error", "traceback": traceback.format_exc(),
        }
        print(rec["traceback"], file=sys.stderr)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(rec, f, indent=1)
        sys.exit(1)
    print(json.dumps({k: v for k, v in rec.items() if k != "traceback"}, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
