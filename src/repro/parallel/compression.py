"""Gradient compression for the cross-pod (DCN) hop.

int8 per-tensor symmetric quantize→dequantize applied to gradients before
the optimizer. Under SPMD the gradient all-reduce over the 'pod' axis then
carries 4× fewer meaningful bits (a real deployment pairs this with a
custom DCN collective; here the numerics and the test coverage are the
point — §Perf records the collective-bytes delta). Error feedback keeps a
residual so quantization error is re-injected next step instead of lost.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def compress_int8(g: jax.Array) -> jax.Array:
    """Quantize-dequantize (simulates the 8-bit wire format)."""
    if g.dtype == jnp.int32 or g.ndim == 0:
        return g
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).astype(g.dtype)


def compress_tree_int8(grads):
    return jax.tree.map(compress_int8, grads)


def compress_with_feedback(grads, residual):
    """Error-feedback variant: residual carries quantization error."""
    def one(g, r):
        if g.ndim == 0:
            return g, r
        gf = g.astype(jnp.float32) + r
        scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(gf / scale), -127, 127)
        deq = q * scale
        return deq.astype(g.dtype), (gf - deq)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_r = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, new_r


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
