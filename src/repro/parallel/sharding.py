"""Logical-axis sharding rules (the pod-scale 'provisioning' policy).

The paper's thesis is that communication should be provisioned to match what
the dataflow needs. At pod scale that decision *is* the logical→mesh axis
mapping below: which tensor dims ride the ICI (``data``/``model`` axes inside
a pod), which must cross the DCN (``pod`` axis), and which stay local.

Hierarchy (mirrors Plaid's local/global datapaths):
  * motif-internal edges  -> stay in VMEM (fused kernels; no mesh axis)
  * intra-pod edges       -> 'data' (batch/FSDP) and 'model' (TP/EP) ICI axes
  * inter-pod edges       -> 'pod' (pure data parallelism; gradient sync only)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import Spec, spec_map

Axis = Union[None, str, Tuple[str, ...]]


def logical_rules(cfg, *, multi_pod: bool = False) -> Dict[str, Axis]:
    """Map logical tensor-dim names to mesh axes for this architecture."""
    rules: Dict[str, Axis] = {
        # activations
        "batch": ("pod", "data") if multi_pod else ("data",),
        "seq": None,
        "cache_seq": ("data",),  # long-context (B=1) decode: shard the KV cache
        # params — tensor/expert parallel over the 'model' ICI axis
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "expert": ("model",),
        # params — FSDP over the 'data' ICI axis (never over the DCN 'pod' axis:
        # pods keep full replicas and sync gradients only — the 'global
        # datapath' carries inter-motif traffic only)
        "embed": ("data",) if cfg.fsdp else None,
        # never sharded
        "layers": None,
        "state": None,
        "conv": None,
        "dt": None,
        "capacity": ("data",),  # MoE dispatch buffer token-capacity dim
    }
    return rules


# production mesh extents — used for divisibility fallbacks (odd vocab sizes
# like whisper's 51865 or granite's 49155 fall back to replicated)
PROD_AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


def _pspec_for(
    axes: Tuple[Optional[str], ...],
    rules: Dict[str, Axis],
    shape,
    axis_sizes: Optional[Dict[str, int]] = None,
) -> P:
    sizes = axis_sizes or PROD_AXIS_SIZES
    parts = []
    used = set()  # a mesh axis may shard at most one dim; first dim wins
    for dim, name in zip(shape, axes):
        if name is None:
            parts.append(None)
            continue
        mapped = rules.get(name)
        if mapped is None:
            parts.append(None)
            continue
        if isinstance(mapped, str):
            mapped = (mapped,)
        if any(a in used for a in mapped):
            parts.append(None)
            continue
        extent = 1
        for a in mapped:
            extent *= sizes.get(a, 1)
        if dim % extent != 0:
            parts.append(None)  # replicate rather than pad unevenly
            continue
        used.update(mapped)
        parts.append(mapped if len(mapped) > 1 else mapped[0])
    return P(*parts)


def shardings_for(spec_tree, mesh: Mesh, cfg, *, multi_pod: bool = False):
    """Spec tree -> NamedSharding tree (divisibility-safe).

    If a dim is not divisible by its mesh-axis extent we keep GSPMD's padded
    sharding *only* for weight matrices (2D+); 1D scales fall back to
    replicated to avoid pathological layouts.
    """
    rules = logical_rules(cfg, multi_pod=multi_pod)
    sizes = {a: mesh.shape[a] for a in mesh.axis_names}

    def one(s: Spec):
        ps = _pspec_for(s.axes, rules, s.shape, sizes)
        return NamedSharding(mesh, ps)

    return spec_map(one, spec_tree)


def pspecs_for(spec_tree, cfg, *, multi_pod: bool = False, axis_sizes=None):
    rules = logical_rules(cfg, multi_pod=multi_pod)
    return spec_map(lambda s: _pspec_for(s.axes, rules, s.shape, axis_sizes), spec_tree)


def batch_pspec(global_batch: int, mesh: Mesh, multi_pod: bool) -> P:
    """Batch-dim spec; falls back to replicated if batch doesn't divide."""
    axes = ("pod", "data") if multi_pod else ("data",)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if global_batch % total == 0:
        return P(axes if len(axes) > 1 else axes[0])
    if global_batch % mesh.shape["data"] == 0:
        return P("data")
    return P(None)


# ---------------------------------------------------------------------------
# In-graph constraints (used by the MoE dispatch path)
# ---------------------------------------------------------------------------


def constrain(x: jax.Array, *axis_names: Optional[str]) -> jax.Array:
    """with_sharding_constraint by mesh-axis names; no-op without a mesh."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return x
        present = [a if (a is None or a in mesh.shape) else None for a in axis_names]
        return jax.lax.with_sharding_constraint(x, P(*present))
    except Exception:
        return x
