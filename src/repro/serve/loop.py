"""Batched serving driver: prefill once, then decode with greedy sampling.

Small-config CPU-runnable; the same ``prefill_step``/``serve_step`` pair is
what the dry-run lowers at production shapes (decode_32k / long_500k).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import zoo
from repro.serve.kvcache import grow_cache
from repro.train import steps as steps_lib


def generate(
    cfg: ModelConfig,
    params,
    prompts: jax.Array,  # (B, T) int32
    max_new_tokens: int = 16,
    extra_batch: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Dict]:
    batch = {"tokens": prompts}
    if extra_batch:
        batch.update(extra_batch)
    prefill = jax.jit(steps_lib.make_prefill_step(cfg))
    cache, logits = prefill(params, batch)
    if max_new_tokens <= 0:
        # exactly zero new tokens: prefill only (cache stays usable for a
        # later decode); the old loop emitted one token here regardless
        tokens = jnp.zeros((prompts.shape[0], 0), jnp.int32)
        return tokens, {"cache_length": int(cache["length"][0])}
    serve = jax.jit(steps_lib.make_serve_step(cfg), donate_argnums=(1,))
    cache = grow_cache(cache, max_new_tokens, window=cfg.sliding_window)
    next_tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    out: List[jax.Array] = [next_tok]
    for _ in range(max_new_tokens - 1):
        cache, next_tok, _ = serve(params, cache, next_tok)
        out.append(next_tok)
    tokens = jnp.concatenate(out, axis=1)
    return tokens, {"cache_length": int(cache["length"][0])}
