"""KV-cache utilities.

``grow_cache`` pads a prefill-produced cache with empty decode headroom —
prefill allocates exactly the prompt length (what the dry-run lowers at
fixed shapes); serving extends it before decoding. SSM states (conv/h) are
constant-size and need no growth; sliding-window ring buffers are already
window-bounded and wrap correctly.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp


def grow_cache(cache: Dict, extra: int, *, window: int = 0) -> Dict:
    if extra <= 0 or "k" not in cache:
        return cache
    S = cache["k"].shape[-2]
    if window:
        # a window-bounded ring never needs to exceed the window; a
        # prompt-sized cache below the window still must grow
        extra = min(window, S + extra) - S
        if extra <= 0:
            return cache
    out = dict(cache)
    for key in ("k", "v"):
        if key in out:
            t = out[key]
            pad = [(0, 0)] * t.ndim
            pad[-2] = (0, extra)  # (..., B, S, kvd): grow S
            out[key] = jnp.pad(t, pad)
    if "pos" in out:
        out["pos"] = jnp.pad(out["pos"], ((0, 0), (0, extra)), constant_values=-1)
    return out
