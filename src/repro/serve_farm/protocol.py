"""Wire format of the compile farm: length-prefixed JSON over a
Unix-domain stream socket.

One frame = a 4-byte big-endian payload length followed by that many
bytes of UTF-8 JSON.  The prefix makes message boundaries explicit (no
sentinel scanning, binary-safe payloads) and lets the receiver reject an
oversized or garbage frame *before* buffering it.  Requests and
responses are single frames; a connection carries one request/response
exchange (idempotent resubmission after a dropped connection is the
client's retry loop, not connection state).

Ops (all requests carry ``{"op": ...}``):

* ``ping``     → liveness probe
* ``status``   → queue depth, in-flight jobs, hit/shed counters, uptime
* ``compile``  → ``{workload, unroll, arch, mapper, seed, budget,
  iterations, verify, deadline_s}``; the response carries the artifact
  JSON and whether it was served warm (``hit``)
* ``shutdown`` → ask the daemon to drain and exit

Error responses are ``{"ok": false, "error": <taxonomy class name>,
"message": ...}`` plus class-specific fields (``queue_depth`` /
``queue_limit`` for ``ServiceOverloaded``); the client re-raises them as
the matching :mod:`repro.compiler.errors` class, so a shed request exits
a CLI with the same typed code remotely as locally.
"""
from __future__ import annotations

import json
import socket
import struct
from typing import Dict

#: hard cap on one frame — far above any artifact, far below a runaway
MAX_FRAME = 64 * 1024 * 1024
_HEADER = struct.Struct(">I")


class ProtocolError(ConnectionError):
    """The peer sent bytes that are not a valid frame (bad length,
    oversized payload, non-JSON body).  A ``ConnectionError`` so client
    retry loops treat a mid-frame-died daemon like a refused one."""


def send_msg(sock: socket.socket, obj: Dict) -> None:
    data = json.dumps(obj, separators=(",", ":")).encode()
    if len(data) > MAX_FRAME:
        raise ProtocolError(f"frame of {len(data)} bytes exceeds "
                            f"MAX_FRAME ({MAX_FRAME})")
    sock.sendall(_HEADER.pack(len(data)) + data)


def recv_msg(sock: socket.socket) -> Dict:
    """Receive one frame; raises :class:`ProtocolError` on a malformed
    one and ``ConnectionError`` when the peer closes mid-frame."""
    header = _recv_exact(sock, _HEADER.size)
    (n,) = _HEADER.unpack(header)
    if n > MAX_FRAME:
        raise ProtocolError(f"peer announced a {n}-byte frame "
                            f"(> MAX_FRAME {MAX_FRAME})")
    payload = _recv_exact(sock, n)
    try:
        obj = json.loads(payload)
    except ValueError as e:
        raise ProtocolError(f"frame payload is not valid JSON: {e}")
    if not isinstance(obj, dict):
        raise ProtocolError("frame payload must be a JSON object")
    return obj


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError(
                f"peer closed the connection mid-frame "
                f"({len(buf)}/{n} bytes received)")
        buf += chunk
    return buf
