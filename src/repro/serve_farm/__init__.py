"""The compile farm: a long-lived ``plaid-compile serve`` daemon over a
Unix-domain socket, plus the retrying remote client behind
``compile(..., remote=)`` and ``collect --remote``.

Layering (see ``docs/serving_farm.md``):

* :mod:`repro.serve_farm.protocol` — length-prefixed JSON frames;
* :mod:`repro.serve_farm.daemon` — :class:`CompileFarm`: cache-first
  lookup, in-flight dedup of identical ``CompileKey``s, a bounded job
  queue with explicit load-shedding, supervised worker processes, and
  graceful drain on SIGTERM;
* :mod:`repro.serve_farm.client` — bounded deterministic retry with
  exponential backoff + jitter, idempotent resubmission, and a
  circuit breaker that degrades to local compiles.
"""
from repro.serve_farm.client import farm_request, farm_status, remote_compile
from repro.serve_farm.daemon import CompileFarm

__all__ = ["CompileFarm", "farm_request", "farm_status", "remote_compile"]
