"""``plaid-compile serve``: the compile-farm daemon.

A :class:`CompileFarm` owns one journaled :class:`ArtifactStore` and a
Unix-domain listener.  Every request follows the same path:

1. **cache first** — the request's ``CompileKey`` is recomputed
   daemon-side (clients send compile *inputs*, never keys, so a stale
   client cannot poison the cache) and served from the store when warm;
2. **in-flight dedup** — a second request for a key already compiling
   attaches to the first one's job instead of spawning a duplicate;
3. **bounded queue** — when queued + running jobs reach ``queue_limit``
   the daemon sheds load with a typed ``ServiceOverloaded`` response
   rather than queueing unboundedly;
4. **supervised workers** — each compile runs in a child process driven
   by :class:`repro.core.runner.SupervisedRunner` (PR 6 semantics:
   per-request ``deadline_s`` with SIGTERM→SIGKILL reclaim, a crashed
   worker becomes a structured failure response, never a hung daemon).

On SIGTERM the daemon drains: the listener closes, queued jobs finish,
new compiles are refused, the store journal is compacted, and the
process exits 0.  A ``kill -9`` instead is exactly the crash the
journaled index recovers from on the next start — the chaos gate in
``scripts/ci.sh`` exercises both.
"""
from __future__ import annotations

import os
import queue
import signal
import socket
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.compiler.errors import CompileError, CompileTimeout
from repro.compiler.store import ArtifactStore, open_store
from repro.serve_farm.protocol import ProtocolError, recv_msg, send_msg

#: compile requests wait on their job at most request deadline + this;
#: the grace covers worker start/reclaim overhead around the runner's
#: own timeout enforcement
_WAIT_GRACE_S = 30.0
_DEFAULT_DEADLINE_S = 600.0

_STOP = object()


def _farm_compile(task):
    """Worker-process entry point (module-level: must pickle under any
    multiprocessing start method).  Runs a normal local compile against
    the shared store, so served artifacts are bit-identical to local
    ones by construction."""
    (store_path, name, unroll, arch, mapper, seed, budget, iterations,
     verify) = task
    from repro.compiler.pipeline import compile as _compile
    out = _compile(
        name, arch=arch, mapper=mapper, seed=seed, budget=budget,
        unroll=unroll, iterations=iterations, verify=verify,
        store=store_path)
    return out.to_json()


@dataclass
class _Job:
    digest: str
    task: tuple
    label: str
    deadline_s: Optional[float]
    retries: int
    done: threading.Event = field(default_factory=threading.Event)
    response: Optional[Dict] = None
    waiters: int = 1


class CompileFarm:
    """The serve daemon.  ``start()``/``shutdown()`` embed it in-process
    (tests); ``serve_forever()`` is the CLI entry and owns signals."""

    def __init__(self, store_path: str, socket_path: str, *,
                 workers: int = 2, queue_limit: int = 8,
                 default_deadline_s: Optional[float] = _DEFAULT_DEADLINE_S,
                 retries: int = 1, start_method: Optional[str] = None):
        self.store_path = str(store_path)
        self.socket_path = str(socket_path)
        self.workers = max(1, int(workers))
        self.queue_limit = max(1, int(queue_limit))
        self.default_deadline_s = default_deadline_s
        self.retries = retries
        self.start_method = start_method
        self.store: ArtifactStore = open_store(self.store_path)
        self._queue: "queue.Queue" = queue.Queue()
        self._jobs: Dict[str, _Job] = {}
        self._lock = threading.Lock()
        self._draining = threading.Event()
        self._threads = []
        self._listener: Optional[socket.socket] = None
        self._t0 = time.time()
        self.counters = {"requests": 0, "hits": 0, "compiles": 0,
                         "dedup_attached": 0, "shed": 0, "failures": 0}

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        if os.path.exists(self.socket_path):
            os.unlink(self.socket_path)  # stale socket from a kill -9
        self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._listener.bind(self.socket_path)
        self._listener.listen(64)
        # poll rather than block: closing a socket does not wake a thread
        # parked in accept(), so a blocking listener would hang the drain
        self._listener.settimeout(0.2)
        for i in range(self.workers):
            t = threading.Thread(target=self._worker_loop, daemon=True,
                                 name=f"farm-worker-{i}")
            t.start()
            self._threads.append(t)
        t = threading.Thread(target=self._accept_loop, daemon=True,
                             name="farm-listener")
        t.start()
        self._threads.append(t)

    def serve_forever(self) -> int:
        stop = threading.Event()
        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, lambda *_: stop.set())
        self.start()
        print(f"serving store {self.store_path} on {self.socket_path} "
              f"(pid {os.getpid()}, {self.workers} workers, "
              f"queue_limit {self.queue_limit})", flush=True)
        stop.wait()
        print("draining: finishing in-flight jobs, refusing new ones",
              flush=True)
        self.shutdown()
        print("drained; journal compacted; bye", flush=True)
        return 0

    def shutdown(self) -> None:
        """Graceful drain: stop accepting, finish queued + in-flight
        jobs, compact the store journal."""
        self._draining.set()
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        for _ in range(self.workers):
            self._queue.put(_STOP)  # after real jobs: workers drain first
        for t in self._threads:
            if t is not threading.current_thread():
                t.join(timeout=self.default_deadline_s or 600.0)
        try:
            self.store.compact()
        except OSError:
            pass
        if os.path.exists(self.socket_path):
            try:
                os.unlink(self.socket_path)
            except OSError:
                pass

    # -- request handling ----------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._draining.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return  # listener closed by drain
            conn.settimeout(None)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        with conn:
            try:
                req = recv_msg(conn)
            except (ConnectionError, OSError):
                return
            try:
                resp = self._dispatch(req)
            except Exception as e:  # a handler bug must not kill the daemon
                resp = {"ok": False, "error": type(e).__name__,
                        "message": str(e)}
            try:
                send_msg(conn, resp)
            except (ConnectionError, OSError):
                pass  # client went away; the job (if any) still caches

    def _dispatch(self, req: Dict) -> Dict:
        op = req.get("op")
        self.counters["requests"] += 1
        if op == "ping":
            return {"ok": True, "op": "ping", "pid": os.getpid()}
        if op == "status":
            return self._status()
        if op == "shutdown":
            threading.Thread(target=self.shutdown, daemon=True).start()
            return {"ok": True, "op": "shutdown", "draining": True}
        if op == "compile":
            return self._handle_compile(req)
        return {"ok": False, "error": "ProtocolError",
                "message": f"unknown op {op!r}"}

    def _status(self) -> Dict:
        with self._lock:
            in_flight = len(self._jobs)
        return {
            "ok": True, "op": "status", "pid": os.getpid(),
            "uptime_s": round(time.time() - self._t0, 3),
            "draining": self._draining.is_set(),
            "workers": self.workers,
            "queue_limit": self.queue_limit,
            "queue_depth": self._queue.qsize(),
            "in_flight": in_flight,
            "counters": dict(self.counters),
            "store": self.store.counters.to_json(),
        }

    def _handle_compile(self, req: Dict) -> Dict:
        from repro.compiler.pipeline import compile_key, serve_from_store

        if self._draining.is_set():
            return {"ok": False, "error": "FarmUnavailable",
                    "message": "daemon is draining; retry elsewhere"}
        name = req.get("workload")
        if not isinstance(name, str):
            return {"ok": False, "error": "ProtocolError",
                    "message": "compile request needs a workload name"}
        unroll = req.get("unroll")
        arch = req.get("arch", "plaid2x2")
        mapper = req.get("mapper", "hierarchical")
        seed = int(req.get("seed", 0))
        budget = req.get("budget")
        iterations = req.get("iterations")
        verify = bool(req.get("verify"))
        deadline_s = req.get("deadline_s")
        if deadline_s is None:
            deadline_s = self.default_deadline_s

        try:
            key = compile_key(name, arch=arch, mapper=mapper, seed=seed,
                              budget=budget, unroll=unroll,
                              iterations=iterations)
        except CompileError as e:
            return self._error_response(e)
        except KeyError as e:
            return {"ok": False, "error": "CompileError",
                    "message": f"unknown workload or arch: {e}"}

        cached = serve_from_store(self.store, key, verify=verify)
        if cached is not None:
            self.counters["hits"] += 1
            return {"ok": True, "hit": True, "artifact": cached.to_json()}

        task = (self.store_path, name, unroll, arch, mapper, seed, budget,
                iterations, verify)
        label = key.describe()
        with self._lock:
            job = self._jobs.get(key.digest)
            if job is not None:
                job.waiters += 1
                self.counters["dedup_attached"] += 1
            else:
                if len(self._jobs) >= self.queue_limit:
                    self.counters["shed"] += 1
                    return {"ok": False, "error": "ServiceOverloaded",
                            "message": f"farm at capacity "
                                       f"({len(self._jobs)} jobs queued or "
                                       f"running); retry with backoff",
                            "queue_depth": len(self._jobs),
                            "queue_limit": self.queue_limit}
                job = _Job(digest=key.digest, task=task, label=label,
                           deadline_s=deadline_s, retries=self.retries)
                self._jobs[key.digest] = job
                self._queue.put(job)

        wait_s = None
        if deadline_s is not None:
            # cover queueing + one reclaimed retry attempt
            wait_s = deadline_s * (1 + max(0, job.retries)) + _WAIT_GRACE_S
        if not job.done.wait(timeout=wait_s):
            timeout = CompileTimeout(
                f"farm job {label} still running after {wait_s:.0f}s wait",
                deadline_s=deadline_s)
            return self._error_response(timeout)
        return dict(job.response)

    def _error_response(self, err: Exception) -> Dict:
        resp = {"ok": False, "error": type(err).__name__,
                "message": str(err)}
        to_json = getattr(err, "to_json", None)
        if callable(to_json):
            try:
                resp["detail"] = to_json()
            except Exception:
                pass
        return resp

    # -- workers -------------------------------------------------------------

    def _worker_loop(self) -> None:
        from repro.core.runner import SupervisedRunner

        while True:
            job = self._queue.get()
            if job is _STOP:
                return
            runner = SupervisedRunner(
                fn=_farm_compile, jobs=1, timeout_s=job.deadline_s,
                retries=job.retries, start_method=self.start_method,
                label=job.label)
            response = None
            try:
                for _task, status, payload in runner.run([job.task]):
                    if status == "ok":
                        self.counters["compiles"] += 1
                        response = {"ok": True, "hit": False,
                                    "artifact": payload}
                    else:
                        self.counters["failures"] += 1
                        response = {"ok": False, "error": payload.error,
                                    "message": payload.message,
                                    "failure": payload.to_json()}
            except Exception as e:  # runner itself blew up
                self.counters["failures"] += 1
                response = {"ok": False, "error": type(e).__name__,
                            "message": str(e)}
            if response is None:
                self.counters["failures"] += 1
                response = {"ok": False, "error": "WorkerCrashed",
                            "message": "runner yielded no result"}
            with self._lock:
                self._jobs.pop(job.digest, None)
                job.response = response
                job.done.set()


def serve(store_path: str, socket_path: str, **kwargs) -> int:
    """CLI entry: build a farm and block until SIGTERM/SIGINT drain."""
    return CompileFarm(store_path, socket_path, **kwargs).serve_forever()
