"""Remote-compile client: bounded deterministic retry, idempotent
resubmission, and a circuit breaker in front of the farm daemon.

Retry policy
------------
Connection-level failures (refused socket, peer died mid-frame, recv
timeout) and ``ServiceOverloaded`` sheds are retried up to ``retries``
times with exponential backoff plus *deterministic* jitter — the jitter
is hashed from ``(addr, attempt, salt)``, never ``random``, so a failing
sweep replays identically.  Resubmission is safe by construction: the
daemon keys jobs by ``CompileKey`` and dedups in-flight work, so a
retried request either attaches to the original job or serves its
cached artifact.

Circuit breaker
---------------
``BREAKER_THRESHOLD`` *consecutive* connection failures open the breaker
for ``BREAKER_COOLDOWN_S``; while open every call raises
:class:`FarmUnavailable` immediately (no socket churn), and
``compile(..., remote=)`` degrades to a local cache-first compile.
After the cooldown one probe is allowed through (half-open); success
closes the breaker.  Breakers are per-address and per-process.

Typed sheds propagate: a request the daemon refused with
``ServiceOverloaded`` exhausts its retries and then raises the same
class locally, so ``plaid-compile`` exits with the same code (17) a
local overload would produce.
"""
from __future__ import annotations

import hashlib
import socket
import threading
import time
from typing import Dict, Optional

from repro.compiler import errors as _errors
from repro.compiler.artifact import CompileResult
from repro.compiler.errors import (
    CompileError,
    FarmUnavailable,
    ServiceOverloaded,
)
from repro.serve_farm.protocol import recv_msg, send_msg

DEFAULT_RETRIES = 4
DEFAULT_BACKOFF_S = 0.05
DEFAULT_TIMEOUT_S = 600.0
BREAKER_THRESHOLD = 3
BREAKER_COOLDOWN_S = 5.0


class _Breaker:
    def __init__(self):
        self.failures = 0
        self.open_until = 0.0
        self._lock = threading.Lock()

    def allow(self) -> bool:
        with self._lock:
            return time.monotonic() >= self.open_until

    def record_failure(self) -> None:
        with self._lock:
            self.failures += 1
            if self.failures >= BREAKER_THRESHOLD:
                self.open_until = time.monotonic() + BREAKER_COOLDOWN_S

    def record_success(self) -> None:
        with self._lock:
            self.failures = 0
            self.open_until = 0.0


_BREAKERS: Dict[str, _Breaker] = {}
_BREAKERS_LOCK = threading.Lock()


def _breaker(addr: str) -> _Breaker:
    with _BREAKERS_LOCK:
        br = _BREAKERS.get(addr)
        if br is None:
            br = _BREAKERS[addr] = _Breaker()
        return br


def reset_breakers() -> None:
    """Forget breaker state (tests; long-lived callers after a redeploy)."""
    with _BREAKERS_LOCK:
        _BREAKERS.clear()


def _jitter(addr: str, attempt: int, salt: str) -> float:
    """Deterministic jitter in [0, 1): same sweep → same schedule."""
    h = hashlib.sha256(f"{addr}:{attempt}:{salt}".encode()).hexdigest()
    return int(h[:8], 16) / 0xFFFFFFFF


def _call(addr: str, request: Dict, timeout_s: float) -> Dict:
    with socket.socket(socket.AF_UNIX, socket.SOCK_STREAM) as s:
        s.settimeout(timeout_s)
        s.connect(addr)
        send_msg(s, request)
        return recv_msg(s)


def farm_request(addr: str, request: Dict, *,
                 retries: int = DEFAULT_RETRIES,
                 backoff_s: float = DEFAULT_BACKOFF_S,
                 timeout_s: float = DEFAULT_TIMEOUT_S,
                 salt: str = "") -> Dict:
    """One request against the farm with the full retry/breaker policy.

    Returns the response dict (which may still be ``{"ok": false}`` for
    non-retryable typed errors — callers map those).  Raises
    :class:`FarmUnavailable` when the daemon is unreachable and
    :class:`ServiceOverloaded` when sheds outlast the retries.
    """
    br = _breaker(addr)
    if not br.allow():
        raise FarmUnavailable(
            f"circuit breaker open for {addr} after "
            f"{br.failures} consecutive connection failures")
    last: Optional[Exception] = None
    for attempt in range(retries + 1):
        if attempt:
            delay = backoff_s * (2 ** (attempt - 1))
            time.sleep(delay * (1.0 + _jitter(addr, attempt, salt)))
        try:
            resp = _call(addr, request, timeout_s)
        except (ConnectionError, socket.timeout, OSError) as e:
            last = e
            br.record_failure()
            if not br.allow():
                break  # breaker tripped mid-loop: stop hammering
            continue
        br.record_success()
        if not resp.get("ok") and resp.get("error") == "ServiceOverloaded":
            last = ServiceOverloaded(
                resp.get("message", "farm shed the request"),
                queue_depth=resp.get("queue_depth"),
                queue_limit=resp.get("queue_limit"))
            continue  # backoff, then try again: the queue may drain
        if not resp.get("ok") and resp.get("error") == "FarmUnavailable":
            last = FarmUnavailable(
                resp.get("message", "daemon is draining"))
            br.record_failure()
            continue  # a draining daemon counts as unreachable
        return resp
    if isinstance(last, ServiceOverloaded):
        raise last
    raise FarmUnavailable(
        f"compile farm at {addr} unreachable after "
        f"{retries + 1} attempt(s): {last}")


def _raise_typed(resp: Dict) -> None:
    """Re-raise a daemon error response as its taxonomy class."""
    name = resp.get("error", "CompileError")
    message = resp.get("message", "remote compile failed")
    cls = getattr(_errors, str(name), None)
    if isinstance(cls, type) and issubclass(cls, CompileError):
        if cls is ServiceOverloaded:
            raise cls(message, queue_depth=resp.get("queue_depth"),
                      queue_limit=resp.get("queue_limit"))
        raise cls(message)
    raise CompileError(f"{name}: {message}")


def remote_compile(addr: str, *, workload: str,
                   unroll: Optional[int] = None,
                   arch: str = "plaid2x2", mapper: str = "hierarchical",
                   seed: int = 0, budget=None,
                   iterations: Optional[int] = None,
                   verify: bool = False,
                   deadline_s: Optional[float] = None,
                   retries: int = DEFAULT_RETRIES,
                   backoff_s: float = DEFAULT_BACKOFF_S,
                   timeout_s: float = DEFAULT_TIMEOUT_S) -> CompileResult:
    """Compile ``workload`` on the farm at ``addr`` and return the
    artifact, marked ``store_hit`` when it was served warm."""
    request = {"op": "compile", "workload": workload, "unroll": unroll,
               "arch": arch, "mapper": mapper, "seed": seed,
               "budget": budget, "iterations": iterations,
               "verify": verify, "deadline_s": deadline_s}
    salt = f"{workload}/u{unroll}/{mapper}/s{seed}"
    resp = farm_request(addr, request, retries=retries,
                        backoff_s=backoff_s, timeout_s=timeout_s,
                        salt=salt)
    if not resp.get("ok"):
        _raise_typed(resp)
    out = CompileResult.from_json(resp["artifact"])
    out.store_hit = bool(resp.get("hit"))
    return out


def farm_status(addr: str, *, timeout_s: float = 10.0) -> Dict:
    """One unretried ``status`` probe (monitoring; bench sidecars)."""
    return _call(addr, {"op": "status"}, timeout_s)


def farm_ping(addr: str, *, timeout_s: float = 10.0) -> bool:
    try:
        return bool(_call(addr, {"op": "ping"}, timeout_s).get("ok"))
    except (ConnectionError, OSError):
        return False
