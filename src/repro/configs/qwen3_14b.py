"""Qwen3-14B — dense GQA transformer with qk-norm.

[dense] 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936 — qk_norm, GQA
[hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="qwen3_14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=17408,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        remat="dots",
        fsdp=True,
        notes="qk-norm per head (RMSNorm on q/k before RoPE), head_dim=128.",
    )
)
