"""Zamba2-1.2B — Mamba2 backbone + shared attention block hybrid.

[hybrid] 38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="zamba2_1_2b",
        family="hybrid",
        n_layers=38,  # Mamba2 blocks
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,  # shared attention block is MHA
        head_dim=64,
        d_ff=8192,
        vocab_size=32000,
        ssm_state=64,
        ssm_variant="mamba2",
        expand=2,
        attn_every=6,  # shared attn block applied every 6 Mamba2 blocks
        remat="dots",
        fsdp=False,
        notes=(
            "One shared transformer block (attn+MLP) reused at every application "
            "site (Zamba trick); per-site LoRA deltas omitted (documented "
            "simplification). Runs long_500k: SSM state is O(1), shared-attn KV "
            "cache sharded over sequence."
        ),
    )
)
