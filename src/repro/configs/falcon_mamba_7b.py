"""Falcon-Mamba-7B — pure Mamba-1 SSM (attention-free).

[ssm] 64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16
[arXiv:2410.05355; unverified]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="falcon_mamba_7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=65024,
        ssm_state=16,
        ssm_variant="mamba1",
        expand=2,
        d_conv=4,
        remat="dots",
        fsdp=True,
        notes=(
            "Attention-free: Plaid's attention-related sharding aspects N/A "
            "(DESIGN.md §4); motif fusion applies to the SSM block DFG. Runs "
            "long_500k with O(1) recurrent state."
        ),
    )
)
