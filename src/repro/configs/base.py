"""Config system for the Plaid-JAX framework.

A ``ModelConfig`` fully describes one architecture from the assigned pool.
``ShapeSpec`` describes one (seq_len, global_batch, kind) input-shape cell.
``RunConfig`` couples a model, a shape, parallelism knobs and training knobs.

All architecture configs live in ``repro.configs.<arch_id>`` and register
themselves in ``ARCH_REGISTRY`` via ``register``.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (family-specific fields default off)."""

    arch_id: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention flavour ---
    qk_norm: bool = False
    sliding_window: int = 0  # 0 = full attention; >0 = SWA window
    rope_theta: float = 10_000.0
    m_rope: bool = False  # Qwen2-VL multimodal RoPE (3 sections)
    m_rope_sections: Tuple[int, int, int] = (16, 24, 24)  # t, h, w (per half-dim)

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_dense_ff: int = 0  # Arctic-style parallel dense residual MLP width
    capacity_factor: float = 1.25

    # --- SSM ---
    ssm_state: int = 0
    ssm_variant: str = ""  # mamba1 | mamba2
    d_conv: int = 4
    expand: int = 2
    ssm_chunk: int = 256  # chunked-scan block size
    ssm_heads: int = 0  # mamba2 value heads (0 -> d_inner // 64)

    # --- hybrid (Zamba2) ---
    attn_every: int = 0  # shared attention block applied every k SSM blocks

    # --- encoder-decoder (Whisper backbone) ---
    n_enc_layers: int = 0
    enc_seq: int = 1500  # audio frame positions (frontend is a stub)

    # --- numerics / memory policy ---
    dtype: str = "bfloat16"
    remat: str = "nothing"  # nothing | dots | full(=no remat)
    attn_impl: str = "banded"  # banded (flash-style) | naive (masked full)
    unroll_layers: bool = False  # roofline harness only (see layers.scan_layers)
    logits_chunk: int = 8192  # chunked cross-entropy block (tokens)
    attn_chunk: int = 1024  # flash-attention KV block (pure-jnp path)

    # --- parallelism hints ---
    fsdp: bool = False  # shard the d_model dim of params over 'data'
    opt_state_dtype: str = "float32"  # bf16 for the 480B-class model

    # free-form notes (source, verification tier, simplifications)
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or max(self.d_inner // 64, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # Parameter count (for MODEL_FLOPS = 6*N*D roofline accounting)
    # ------------------------------------------------------------------
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd
        attn = d * q + 2 * d * kv + q * d  # wq, wk, wv, wo
        norms = 2 * d
        if self.qk_norm:
            norms += 2 * hd
        mlp_dense = 3 * d * self.d_ff
        per_layer: int
        if self.family in ("dense", "vlm"):
            per_layer = attn + mlp_dense + norms
            n = self.n_layers * per_layer
        elif self.family == "moe":
            router = d * self.n_experts
            n_exp = self.n_experts if not active_only else self.top_k
            experts = n_exp * 3 * d * self.d_ff
            dense_res = 3 * d * self.moe_dense_ff if self.moe_dense_ff else 0
            per_layer = attn + router + experts + dense_res + norms
            n = self.n_layers * per_layer
        elif self.family == "ssm":
            di, ns = self.d_inner, self.ssm_state
            per_layer = (
                d * 2 * di  # in_proj
                + di * self.d_conv  # depthwise conv
                + di * (2 * ns + di // 16 + 1)  # x_proj(B,C,dt) approx + dt_proj
                + di * ns  # A_log
                + di  # D
                + di * d  # out_proj
                + d
            )
            n = self.n_layers * per_layer
        elif self.family == "hybrid":
            di, ns = self.d_inner, self.ssm_state
            ssm_layer = d * 2 * di + di * self.d_conv + 3 * di + di * ns + di * d + d
            shared_attn = attn + mlp_dense + norms  # one shared block
            n = self.n_layers * ssm_layer + shared_attn
        elif self.family == "encdec":
            enc = self.n_enc_layers * (attn + mlp_dense + norms)
            dec = self.n_layers * (2 * attn + mlp_dense + norms + d)
            n = enc + dec
        else:
            raise ValueError(self.family)
        n += self.vocab_size * d  # tied embedding / output head
        return n


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Assignment skip rules (documented in DESIGN.md §4)."""
    if shape.name == "long_500k":
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid") or cfg.sliding_window > 0
        )
        if not sub_quadratic:
            return False, "pure full-attention arch: long_500k skipped per assignment"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_REGISTRY: Dict[str, ModelConfig] = {}

ARCH_IDS = [
    "stablelm_12b",
    "qwen3_14b",
    "llama3_2_3b",
    "h2o_danube_3_4b",
    "zamba2_1_2b",
    "whisper_tiny",
    "arctic_480b",
    "granite_moe_1b_a400m",
    "falcon_mamba_7b",
    "qwen2_vl_72b",
]


def register(cfg: ModelConfig) -> ModelConfig:
    ARCH_REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    if arch_id not in ARCH_REGISTRY:
        importlib.import_module(f"repro.configs.{arch_id}")
    return ARCH_REGISTRY[arch_id]


def all_configs() -> Dict[str, ModelConfig]:
    for a in ARCH_IDS:
        get_config(a)
    return dict(ARCH_REGISTRY)


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------


def smoke_config(arch_id: str) -> ModelConfig:
    """Tiny same-family config: small layers/width/experts/vocab."""
    cfg = get_config(arch_id)
    kw: Dict[str, Any] = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        logits_chunk=64,
        attn_chunk=32,
        ssm_chunk=16,
        fsdp=False,
        opt_state_dtype="float32",
    )
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=2, moe_dense_ff=64 if cfg.moe_dense_ff else 0)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=8, ssm_heads=4)
    if cfg.family == "hybrid":
        kw.update(attn_every=2, n_kv_heads=4)  # zamba2 uses MHA
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2, enc_seq=16)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    if cfg.m_rope:  # scale M-RoPE sections to the reduced head_dim
        half = kw["head_dim"] // 2
        t = half - 2 * (half // 3)
        kw.update(m_rope_sections=(t, half // 3, half // 3))
    return cfg.replace(**kw)


@dataclass(frozen=True)
class RunConfig:
    """One launchable run = model x shape x mesh/parallelism x training."""

    model: ModelConfig
    shape: ShapeSpec
    multi_pod: bool = False
    # training knobs
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    grad_accum: int = 1
    seed: int = 0
    # fault tolerance
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    # distributed-optimization tricks
    grad_compression: str = "none"  # none | int8  (DCN/pod-axis hop)
    straggler_threshold: float = 3.0  # x median step time -> flagged
