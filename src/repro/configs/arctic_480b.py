"""Snowflake Arctic 480B — 128-expert top-2 MoE with dense residual branch.

[moe] 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2
[hf:Snowflake/snowflake-arctic-base; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="arctic_480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32000,
        n_experts=128,
        top_k=2,
        moe_dense_ff=4864,  # Arctic's dense-residual MLP in parallel with MoE
        capacity_factor=1.25,
        rope_theta=10_000.0,
        remat="dots",
        fsdp=True,
        opt_state_dtype="bfloat16",  # 480B-class: bf16 m/v halves optimizer HBM
        notes=(
            "~470B params; experts sharded over 'model' (EP), d_model dim over "
            "'data' (FSDP). bf16 optimizer states keep the 256-chip pod within "
            "HBM (documented in EXPERIMENTS.md)."
        ),
    )
)
