"""StableLM-2-12B — dense GQA transformer.

[dense] 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352
[hf:stabilityai/stablelm-2-1_6b; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="stablelm_12b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=160,
        d_ff=13824,
        vocab_size=100352,
        rope_theta=10_000.0,
        remat="dots",
        fsdp=True,
        notes="12B dense; head_dim=160 (d_model/n_heads).",
    )
)
