"""Llama-3.2-3B — small llama3 dense GQA transformer.

[dense] 28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256
[hf:meta-llama/Llama-3.2-1B; unverified]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="llama3_2_3b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=128256,
        rope_theta=500_000.0,
        remat="dots",
        fsdp=False,
        notes="llama3-style; 3B fits replicated-over-data comfortably.",
    )
)
