"""H2O-Danube3-4B — llama+mistral mix with sliding-window attention.

[dense] 24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000 — SWA
[arXiv:2401.16818; unverified]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="h2o_danube_3_4b",
        family="dense",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        head_dim=120,
        d_ff=10240,
        vocab_size=32000,
        sliding_window=4096,
        rope_theta=10_000.0,
        remat="dots",
        fsdp=False,
        notes="SWA window=4096 (mistral-style); runs long_500k via window KV cache.",
    )
)
