"""IBM Granite-3.0-1B-A400M — 32-expert top-8 MoE.

[moe] 24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="granite_moe_1b_a400m",
        family="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab_size=49155,
        n_experts=32,
        top_k=8,
        moe_dense_ff=0,  # no dense residual branch
        capacity_factor=1.25,
        rope_theta=10_000.0,
        remat="nothing",
        fsdp=False,
        notes="1B total / ~400M active; tiny experts stress the dispatch path.",
    )
)
