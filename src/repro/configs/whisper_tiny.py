"""Whisper-tiny — encoder-decoder audio backbone (conv frontend stubbed).

[audio] 4L d_model=384 6H (GQA kv=6) d_ff=1536 vocab=51865 — enc-dec
[arXiv:2212.04356; unverified]

The modality frontend is a STUB per the assignment: ``input_specs()``
provides precomputed audio-frame embeddings (B, enc_seq, d_model).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="whisper_tiny",
        family="encdec",
        n_layers=4,  # decoder layers
        n_enc_layers=4,
        enc_seq=1500,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51865,
        rope_theta=10_000.0,  # we use RoPE in place of learned abs-pos (noted)
        remat="dots",
        fsdp=False,
        notes=(
            "Backbone only; mel-spectrogram conv frontend stubbed with "
            "precomputed frame embeddings. Decoder has self+cross attention."
        ),
    )
)
