from repro.configs.base import (
    ARCH_IDS,
    ARCH_REGISTRY,
    SHAPES,
    ModelConfig,
    RunConfig,
    ShapeSpec,
    all_configs,
    get_config,
    register,
    shape_applicable,
    smoke_config,
)

__all__ = [
    "ARCH_IDS",
    "ARCH_REGISTRY",
    "SHAPES",
    "ModelConfig",
    "RunConfig",
    "ShapeSpec",
    "all_configs",
    "get_config",
    "register",
    "shape_applicable",
    "smoke_config",
]
