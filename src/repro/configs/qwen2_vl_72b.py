"""Qwen2-VL-72B — VLM backbone with M-RoPE (vision frontend stubbed).

[vlm] 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 — M-RoPE
[arXiv:2409.12191; hf]

The vision patch-embedding frontend is a STUB per the assignment:
``input_specs()`` provides precomputed patch/token embeddings (B, T, d)
plus 3-axis (t, h, w) M-RoPE position ids.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="qwen2_vl_72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        m_rope=True,
        m_rope_sections=(16, 24, 24),
        rope_theta=1_000_000.0,
        remat="dots",
        fsdp=True,
        notes="72B backbone; dynamic-resolution handled by the (stubbed) frontend.",
    )
)
