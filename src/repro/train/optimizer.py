"""AdamW with configurable state dtype (bf16 states for the 480B-class
model keep the 256-chip pod within HBM), global-norm clipping, and a
warmup+cosine schedule. Optimizer state mirrors the param logical axes so
m/v shard exactly like their parameters (ZeRO-style when fsdp is on).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Spec, spec_map


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def opt_state_spec(param_specs, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    mv = spec_map(lambda s: Spec(s.shape, s.axes, dt, init="zeros"), param_specs)
    return {
        "m": mv,
        "v": mv,
        "step": Spec((), (), jnp.int32, init="zeros"),
    }


def init_opt_state(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(z, params),
        "v": jax.tree.map(z, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def apply_updates(
    params, grads, opt_state, cfg: AdamWConfig
) -> Tuple[Any, Dict, Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) if cfg.grad_clip else 1.0
    sdt = jnp.dtype(cfg.state_dtype)

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m1 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v1 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m1 / bc1
        vh = v1 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m1.astype(sdt), v1.astype(sdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
