"""Sharded checkpointing with manifest + elastic re-sharding.

Layout:  <dir>/step_<n>/
           manifest.json      (step, tree structure, shapes/dtypes, rng)
           arrays.npz         (flat param + optimizer state leaves)

Arrays are saved from fully-addressable host values (this container is a
single process; on a real multi-host pod each host would write only its
addressable shards and the manifest records the global shapes — the load
path below already re-shards to WHATEVER mesh the restarted job brings up,
which is the elastic-scaling path: restore on fewer/more devices than the
writer had).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

try:
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except Exception:  # pragma: no cover
    _BF16 = None


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, state: Dict[str, Any], keep: int = 3) -> str:
    """state: {'params': tree, 'opt_state': tree, 'extra': jsonable}."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays = {}
    manifest: Dict[str, Any] = {"step": step, "time": time.time(), "leaves": {}}
    for group in ("params", "opt_state"):
        for key, leaf in _flatten(state[group]).items():
            full = f"{group}/{key}"
            arr = np.asarray(jax.device_get(leaf))
            dtype_name = str(arr.dtype)
            if _BF16 is not None and arr.dtype == _BF16:
                arr = arr.view(np.uint16)  # npz cannot hold bf16
                dtype_name = "bfloat16"
            arrays[full] = arr
            manifest["leaves"][full] = {
                "shape": list(arr.shape),
                "dtype": dtype_name,
            }
    manifest["extra"] = state.get("extra", {})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)  # atomic publish: a crash never leaves a torn ckpt
    _gc(ckpt_dir, keep)
    return path


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    step: int,
    like: Dict[str, Any],
    shardings: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Restore into the structure of ``like`` ({'params':…, 'opt_state':…}).

    ``shardings``: matching tree of NamedSharding — pass the CURRENT mesh's
    shardings to re-shard elastically (the saved mesh size is irrelevant:
    arrays are global, device_put re-lays them out).
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    out: Dict[str, Any] = {"extra": manifest.get("extra", {})}
    for group in ("params", "opt_state"):
        flat_like = _flatten(like[group])
        flat_sh = _flatten(shardings[group]) if shardings else {}
        rebuilt = {}
        for key, leaf in flat_like.items():
            full = f"{group}/{key}"
            arr = data[full]
            if manifest["leaves"][full]["dtype"] == "bfloat16" and _BF16 is not None:
                arr = arr.view(_BF16)
            assert list(arr.shape) == list(leaf.shape), (full, arr.shape, leaf.shape)
            if shardings and key in flat_sh:
                rebuilt[key] = jax.device_put(arr, flat_sh[key])
            else:
                rebuilt[key] = jax.numpy.asarray(arr)
        out[group] = _unflatten_like(like[group], rebuilt)
    return out


def _unflatten_like(like, flat: Dict[str, Any]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, _ in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)
