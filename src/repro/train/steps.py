"""jit-able step functions: train_step / prefill_step / serve_step.

These are what the dry-run lowers and what the real launcher runs. Gradient
sync across pods is implicit in the shardings (batch rides ('pod','data')),
with optional int8 compression applied to the DCN hop via
``parallel.compression`` when enabled.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import zoo
from repro.train import optimizer as opt_lib


def make_train_step(cfg: ModelConfig, run: RunConfig):
    ocfg = opt_lib.AdamWConfig(
        learning_rate=run.learning_rate,
        weight_decay=run.weight_decay,
        grad_clip=run.grad_clip,
        warmup_steps=run.warmup_steps,
        total_steps=run.total_steps,
        state_dtype=cfg.opt_state_dtype,
    )

    def train_step(params, opt_state, batch):
        def lf(p):
            loss, metrics = zoo.loss_fn(cfg, p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        if run.grad_compression == "int8":
            from repro.parallel.compression import compress_tree_int8

            grads = compress_tree_int8(grads)
        params, opt_state, om = opt_lib.apply_updates(params, grads, opt_state, ocfg)
        metrics = dict(metrics, **om)
        return params, opt_state, metrics

    return train_step


def make_grad_accum_step(cfg: ModelConfig, run: RunConfig):
    """Micro-batched gradient accumulation (scan over microbatches)."""
    assert run.grad_accum > 1
    ocfg = opt_lib.AdamWConfig(
        learning_rate=run.learning_rate,
        weight_decay=run.weight_decay,
        grad_clip=run.grad_clip,
        warmup_steps=run.warmup_steps,
        total_steps=run.total_steps,
        state_dtype=cfg.opt_state_dtype,
    )

    def step(params, opt_state, batch):
        # batch leaves: (accum, micro_batch, ...)
        def micro(acc, mb):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: zoo.loss_fn(cfg, p, mb), has_aux=True
            )(params)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return acc, loss

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        acc, losses = jax.lax.scan(micro, zero, batch)
        grads = jax.tree.map(lambda g: g / run.grad_accum, acc)
        params, opt_state, om = opt_lib.apply_updates(params, grads, opt_state, ocfg)
        return params, opt_state, dict(loss=jnp.mean(losses), **om)

    return step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        cache, logits = zoo.prefill(cfg, params, batch)
        return cache, logits

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens):
        """One decode step; greedy next-token."""
        new_cache, logits = zoo.decode_step(cfg, params, cache, tokens)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return new_cache, next_tok, logits

    return serve_step
