"""Deterministic sharded synthetic-token pipeline with background prefetch.

Every batch is a pure function of (seed, step) — so a restarted or
re-sharded job resumes bit-identically (fault tolerance requirement), and
any data-parallel worker can regenerate exactly its shard without
coordination (how a 1000-node fleet avoids a central data server for this
synthetic workload; a real corpus would swap in an equivalent
seekable-by-step reader).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


def batch_for_step(
    cfg: ModelConfig, shape: ShapeSpec, seed: int, step: int
) -> Dict[str, np.ndarray]:
    """The batch for one optimizer step (global view)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    B, T = shape.global_batch, shape.seq_len
    out: Dict[str, np.ndarray] = {}
    if cfg.family == "vlm":
        out["embeds"] = rng.standard_normal((B, T, cfg.d_model), np.float32).astype(
            np.float32
        ) * 0.02
        pos = np.broadcast_to(np.arange(T, dtype=np.int32), (B, T))
        out["positions"] = np.stack([pos, pos, pos], axis=1)
    elif cfg.family == "encdec":
        out["audio_embeds"] = rng.standard_normal(
            (B, cfg.enc_seq, cfg.d_model), np.float32
        ).astype(np.float32) * 0.02
        out["tokens"] = rng.integers(0, cfg.vocab_size, (B, T), dtype=np.int32)
    else:
        out["tokens"] = rng.integers(0, cfg.vocab_size, (B, T), dtype=np.int32)
    if "tokens" in out:
        out["labels"] = np.roll(out["tokens"], -1, axis=1)
    else:
        out["labels"] = rng.integers(0, cfg.vocab_size, (B, T), dtype=np.int32)
    return out


class Prefetcher:
    """Background-thread double buffering (overlap host data gen with step)."""

    def __init__(self, cfg, shape, seed: int, start_step: int = 0, depth: int = 2):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = batch_for_step(self.cfg, self.shape, self.seed, step)
            try:
                self.q.put((step, batch), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
