"""Fault-tolerant training driver.

Production behaviours exercised here (and covered by tests):
  * checkpoint every N steps + atomic publish; auto-resume from latest;
  * step retry: a transient failure (injected in tests) re-runs the step
    from the last known-good state instead of killing the job;
  * straggler watchdog: steps slower than ``straggler_threshold`` × the
    running median are logged with their step index (on a pod this feeds
    the scheduler's replace-node decision);
  * elastic restart: ``restore`` re-shards onto whatever mesh exists now;
  * deterministic data: (seed, step) → batch, so retries/restarts are
    bit-identical.
"""
from __future__ import annotations

import logging
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import zoo
from repro.models.layers import init_of, shapes_of
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train import steps as steps_lib
from repro.train.data import batch_for_step

log = logging.getLogger("repro.train")


class StragglerWatchdog:
    def __init__(self, threshold: float = 3.0, window: int = 32):
        self.threshold = threshold
        self.times: List[float] = []
        self.window = window
        self.flagged: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        slow = False
        if len(self.times) >= 8:
            med = statistics.median(self.times[-self.window:])
            if dt > self.threshold * med:
                self.flagged.append(step)
                log.warning("straggler: step %d took %.3fs (median %.3fs)", step, dt, med)
                slow = True
        self.times.append(dt)
        return slow


def train(
    run: RunConfig,
    *,
    steps: int,
    rng_seed: int = 0,
    fail_hook: Optional[Callable[[int], None]] = None,
    mesh=None,
) -> Dict[str, Any]:
    """Train for ``steps`` optimizer steps (small configs: CPU-runnable)."""
    cfg = run.model
    pspec = zoo.param_spec(cfg)
    params = init_of(pspec, jax.random.PRNGKey(rng_seed))
    ocfg = opt_lib.AdamWConfig(
        learning_rate=run.learning_rate,
        warmup_steps=run.warmup_steps,
        total_steps=run.total_steps,
        weight_decay=run.weight_decay,
        grad_clip=run.grad_clip,
        state_dtype=cfg.opt_state_dtype,
    )
    opt_state = opt_lib.init_opt_state(params, ocfg)

    start = 0
    last = ckpt_lib.latest_step(run.checkpoint_dir)
    if last is not None:
        state = ckpt_lib.restore(
            run.checkpoint_dir, last,
            {"params": params, "opt_state": opt_state},
        )
        params, opt_state = state["params"], state["opt_state"]
        start = last
        log.info("resumed from step %d", start)

    step_fn = jax.jit(steps_lib.make_train_step(cfg, run))
    wd = StragglerWatchdog(run.straggler_threshold)
    losses: List[float] = []
    step = start
    while step < steps:
        batch = {
            k: jax.numpy.asarray(v)
            for k, v in batch_for_step(cfg, run.shape, run.seed, step).items()
        }
        t0 = time.time()
        try:
            if fail_hook is not None:
                fail_hook(step)  # test hook: may raise to simulate node loss
            new_params, new_opt, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
        except RuntimeError as e:  # transient failure: retry from good state
            log.warning("step %d failed (%s); retrying", step, e)
            continue
        params, opt_state = new_params, new_opt
        wd.observe(step, time.time() - t0)
        losses.append(float(metrics["loss"]))
        step += 1
        if run.checkpoint_every and step % run.checkpoint_every == 0:
            ckpt_lib.save(
                run.checkpoint_dir, step,
                {"params": params, "opt_state": opt_state,
                 "extra": {"losses_tail": losses[-4:]}},
                keep=run.keep_checkpoints,
            )
    return {
        "params": params,
        "opt_state": opt_state,
        "losses": losses,
        "stragglers": wd.flagged,
        "final_step": step,
    }
